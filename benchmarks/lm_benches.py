"""LM-side benchmarks: the §Roofline table from the dry-run artifacts, the
DBG-vocabulary coverage curve (K2), stable-bin MoE dispatch vs sort dispatch
(K3), and wall-clock microbenches of the graph kernels."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.vocab import reorder_vocab, zipf_frequencies
from repro.lm import model as model_mod
from repro.lm import moe as moe_mod
from repro.roofline.analysis import HW, model_flops

from . import common

DRYRUN_JSON = os.path.join(common.RESULTS_DIR, "dryrun.json")


def _arch_params(arch: str):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: model_mod.init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    routed = 0
    if cfg.n_experts:
        f = cfg.moe_d_ff or cfg.d_ff
        routed = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * f
    active = total - int(routed * (1 - cfg.top_k / max(1, cfg.n_experts)))
    return cfg, total, active


def lm_roofline():
    """§Roofline: per (arch x shape x mesh) three terms + dominant +
    MODEL_FLOPS/HLO_FLOPs ratio, from the dry-run JSON."""
    t0 = time.perf_counter()
    if not os.path.exists(DRYRUN_JSON):
        return 0.0, {"error": "run repro.launch.dryrun first"}
    data = json.load(open(DRYRUN_JSON))
    hw = HW()
    table = {}
    for key, cell in sorted(data.items()):
        if cell.get("status") != "ok":
            continue
        arch, shape, mesh = key.split("|")
        if mesh != "single":
            continue  # roofline table is single-pod (assignment)
        cfg, total, active = _arch_params(arch)
        b = {"train_4k": (256, 4096), "prefill_32k": (32, 32768),
             "decode_32k": (128, 1), "long_500k": (1, 1)}[shape]
        tokens = b[0] * b[1]
        kind = cell["kind"]
        mf = model_flops(active, tokens, kind)
        hlo_total = cell["per_device"]["flops"] * cell["n_devices"]
        r = dict(cell["roofline"])
        r["model_flops_ratio"] = round(mf / hlo_total, 3) if hlo_total else None
        r["peak_gib"] = round(cell["per_device"]["peak_bytes"] / 2 ** 30, 2)
        r["fits_16g"] = bool(cell["per_device"]["peak_bytes"] < 16 * 2 ** 30)
        for t in ("compute_s", "memory_s", "collective_s", "bound_s"):
            r[t] = float(f"{r[t]:.3e}")
        table[f"{arch}|{shape}"] = r
    common.save_json("lm_roofline_table.json", table)
    return (time.perf_counter() - t0) * 1e6, {
        k: {"dominant": v["dominant"], "bound_s": v["bound_s"],
            "fits_16g": v["fits_16g"]}
        for k, v in table.items()}


def k2_vocab_coverage():
    """DBG-vocabulary hot coverage: fraction of token lookups served by the
    replicated hot panel vs panel size (the paper's Table III/IV for vocab)."""
    t0 = time.perf_counter()
    out = {}
    for vocab, tag in [(64000, "yi"), (256206, "seamless")]:
        freq = zipf_frequencies(vocab, seed=0)
        row = {}
        for hot_groups in [1, 2, 3, 4]:
            vr = reorder_vocab(freq, hot_group_count=hot_groups)
            row[f"hot_groups_{hot_groups}"] = {
                "hot_rows": int(vr.hot_rows),
                "rows_pct": round(100 * vr.hot_rows / vocab, 2),
                "coverage_pct": round(100 * vr.coverage, 1),
            }
        out[tag] = row
    common.save_json("k2_vocab_coverage.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def k3_moe_dispatch():
    """Stable-bin (DBG) dispatch vs argsort dispatch: same routing, measured
    wall time + order preservation."""
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    t, k, e = 16384, 2, 8
    ids = jnp.asarray(rng.integers(0, e, (t, k)).astype(np.int32))
    cap = int(t * k * 1.25 / e)

    stable = jax.jit(lambda i: moe_mod.stable_bin_dispatch(i, e, cap))
    stable(ids)[0].block_until_ready()
    t1 = time.perf_counter()
    for _ in range(5):
        rank, keep = stable(ids)
    rank.block_until_ready()
    stable_us = (time.perf_counter() - t1) / 5 * 1e6

    def sort_dispatch(i):
        flat = i.reshape(-1)
        order = jnp.argsort(flat)  # the "Sort" baseline: destroys order
        return order

    sortd = jax.jit(sort_dispatch)
    sortd(ids).block_until_ready()
    t1 = time.perf_counter()
    for _ in range(5):
        o = sortd(ids)
    o.block_until_ready()
    sort_us = (time.perf_counter() - t1) / 5 * 1e6

    # order preservation check
    fe, fr = np.asarray(ids).reshape(-1), np.asarray(rank).reshape(-1)
    stable_ok = all(np.all(np.diff(fr[fe == x]) > 0) for x in range(e))
    out = {"stable_bin_us": round(stable_us, 1), "argsort_us": round(sort_us, 1),
           "stable_preserves_order": bool(stable_ok),
           "tokens": t, "experts": e, "top_k": k, "capacity": cap}
    common.save_json("k3_moe_dispatch.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def k1_spmv_occupancy():
    """Degree-binned SpMV: per-group lane occupancy (padding waste bound) and
    wall time vs the segment-sum edge map."""
    from repro.apps import to_arrays
    from repro.core.reorder import dbg_spec, reorder_graph
    from repro.kernels.csr_spmv.ops import dbg_spmv, ell_pack_groups
    from repro.kernels.csr_spmv.ref import csr_spmv_ref

    t0 = time.perf_counter()
    g = common.graph("wl", "small")
    g2, _ = reorder_graph(g, "dbg", degree_source="in")
    spec = dbg_spec(max(1.0, g2.in_degrees().mean()))
    groups = ell_pack_groups(g2, spec.boundaries, row_tile=64, width_tile=128)
    # lane occupancy over REAL rows (row-tile padding excluded): the paper's
    # geometric-bin argument bounds WIDTH padding within a group
    occ = {
        f"group_w{gr.idx.shape[1]}": round(
            float(gr.w[: gr.num_rows].sum()
                  / max(1, gr.num_rows * gr.idx.shape[1])), 3)
        for gr in groups
    }
    x = jnp.asarray(np.random.default_rng(0).random(g2.num_vertices,
                                                    np.float32))
    ga = to_arrays(g2)
    ref = jax.jit(lambda xx: csr_spmv_ref(xx, ga.in_src, ga.in_dst, ga.in_w,
                                          g2.num_vertices))
    ref(x).block_until_ready()
    t1 = time.perf_counter()
    for _ in range(5):
        y = ref(x)
    y.block_until_ready()
    ref_us = (time.perf_counter() - t1) / 5 * 1e6
    out = {"lane_occupancy": occ, "segment_sum_us": round(ref_us, 1),
           "note": "kernel validated vs oracle in interpret mode; "
                   "occupancy >= 0.5 within hot groups by geometric binning"}
    common.save_json("k1_spmv_occupancy.json", out)
    return (time.perf_counter() - t0) * 1e6, out


BENCHES = [lm_roofline, k2_vocab_coverage, k3_moe_dispatch, k1_spmv_occupancy]
