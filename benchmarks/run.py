# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import json

from . import beyond_paper, lm_benches, paper_figures, paper_tables, serve_qps


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on bench function names")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace of the run (one span per "
                         "bench on top of the library's own spans) and save "
                         "it here — load in Perfetto / chrome://tracing")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()
    else:
        obs_trace = None

    benches = (paper_tables.BENCHES + paper_figures.BENCHES
               + lm_benches.BENCHES + beyond_paper.BENCHES
               + serve_qps.BENCHES)
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            if obs_trace is not None:
                with obs_trace.span(f"bench.{fn.__name__}", cat="bench"):
                    us, derived = fn()
            else:
                us, derived = fn()
            print(f"{fn.__name__},{us:.0f},"
                  f"\"{json.dumps(derived, default=str)[:600]}\"", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},-1,\"ERROR: {e}\"", flush=True)
    if args.trace:
        print(f"# trace -> {obs_trace.save(args.trace)}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
