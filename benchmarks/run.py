# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import json
import sys

from . import beyond_paper, lm_benches, paper_figures, paper_tables, serve_qps


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    benches = (paper_tables.BENCHES + paper_figures.BENCHES
               + lm_benches.BENCHES + beyond_paper.BENCHES
               + serve_qps.BENCHES)
    print("name,us_per_call,derived")
    failures = 0
    for fn in benches:
        if only and only not in fn.__name__:
            continue
        try:
            us, derived = fn()
            print(f"{fn.__name__},{us:.0f},"
                  f"\"{json.dumps(derived, default=str)[:600]}\"", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{fn.__name__},-1,\"ERROR: {e}\"", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
