"""Streaming-churn benchmark → BENCH_stream.json.

The streaming analogue of the paper's Figure 9 structure-vs-footprint
tension: as update batches land, how fast does ingest run, how long do
queries take, what does keeping the DBG layout current cost online, and how
much locality does it retain vs. letting the layout rot?

For each (dataset, batch size, layout policy) cell:

  * ingest throughput (edges/s) over a preferential-attachment update stream
    (insert/delete mix; skew-preserving endpoint sampling),
  * incremental-PageRank query latency after every batch,
  * incremental regroup cost per batch vs. a full batch DBG reorder of the
    final graph (the ISSUE 2 acceptance ratio),
  * final-layout quality: L2/L3 MPKA of the final graph under the
    incrementally-maintained mapping vs. a fresh batch DBG mapping vs.
    identity.

``--sweep-h`` additionally sweeps the regrouper's hysteresis band (the
streaming analogue of the paper's Table VII sensitivity): per dataset, how
many vertices move per batch and what the FINAL layout's MPKA is as ``h``
widens — the churn-vs-locality dial, folded into BENCH_stream.json as the
``hysteresis_sweep`` section.

``--dist`` adds the ``dist_ingest`` section (PR 10): sustained sharded
streaming ingest — the same churn schedule driven through a single-device
``StreamService`` and a ``ShardedStreamService`` side by side, per (dataset,
backend, device count, batch size): per-batch O(delta) routing cost vs ONE
full ``shard_graph`` rebuild (the O(E) alternative), with parity columns
(SSSP bitwise, PR max deviation) asserted inside the benchmark.

Usage:
  PYTHONPATH=src python benchmarks/stream_churn.py [--scale small]
      [--datasets kr,uni] [--batch-sizes 256,1024,4096] [--batches 10]
      [--sweep-h 0,0.125,0.25,0.5,1.0] [--dist] [--dist-devices 1,2,4,8]
      [--out BENCH_stream.json] [--smoke]
"""
import os

if "REPRO_DIST_DEVICES" in os.environ:
    # must land before jax is first imported (via repro.stream below)
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DIST_DEVICES"])

import argparse
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np

from repro.cachesim import scaled_hierarchy
from repro.core import reorder
from repro.graph import csr as csr_mod
from repro.graph import datasets
from repro.stream import StreamConfig, StreamService, layout_mpka

POLICIES = ("identity", "incremental_dbg")


class ChurnStream:
    """Skew-preserving update stream: preferential endpoints for inserts,
    uniform eviction over current edges for deletes."""

    def __init__(self, g, insert_frac: float = 0.75, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.insert_frac = insert_frac
        out_p = (g.out_degrees() + 1.0)
        in_p = (g.in_degrees() + 1.0)
        self._out_cum = np.cumsum(out_p / out_p.sum())
        self._in_cum = np.cumsum(in_p / in_p.sum())

    def _pick(self, cum, k):
        # clip: float rounding can leave cum[-1] a hair under 1.0
        idx = np.searchsorted(cum, self.rng.random(k))
        return np.minimum(idx, cum.shape[0] - 1).astype(np.int64)

    def next_batch(self, dg, batch_size: int):
        n_add = int(round(batch_size * self.insert_frac))
        n_del = batch_size - n_add
        add_src = self._pick(self._out_cum, n_add)
        add_dst = self._pick(self._in_cum, n_add)
        es, ed, _ = dg.alive_edges()
        idx = self.rng.choice(es.shape[0], size=min(n_del, es.shape[0]),
                              replace=False)
        return add_src, add_dst, es[idx], ed[idx]


def bench_cell(key: str, scale: str, policy: str, batch_size: int,
               num_batches: int, seed: int = 3, shared_final=None):
    g = datasets.load(key, scale, seed=seed)
    cfg = StreamConfig(
        regroup_every=1 if policy == "incremental_dbg" else 0)

    # Two identical passes over the same deterministic stream: the first is a
    # throwaway that absorbs every jit compilation (the delta-buffer pad size
    # grows with applied batches, so warming up only the initial shape is not
    # enough), the second is timed.  Without this, whichever POLICY ran first
    # in the process would absorb all compiles and the policy-vs-policy
    # latency comparison would be a run-order artifact.
    for warmup in (True, False):
        svc = StreamService(g, cfg)
        stream = ChurnStream(g, seed=seed)
        svc.pagerank()  # initial full solve
        ingest_s, query_s, regroup_s, moved, pr_iters = [], [], [], [], []
        edges_applied = 0
        for _ in range(num_batches):
            a_s, a_d, d_s, d_d = stream.next_batch(svc.dg, batch_size)
            st = svc.ingest(add_src=a_s, add_dst=a_d, del_src=d_s, del_dst=d_d)
            t0 = time.perf_counter()
            svc.pagerank()
            query_s.append(time.perf_counter() - t0)
            ingest_s.append(st.total_seconds)
            regroup_s.append(st.regroup_seconds)
            moved.append(st.moved_vertices)
            pr_iters.append(svc.pr.last_iters)
            edges_applied += st.inserted + st.deleted

    # Final-graph metrics are identical across policies (the stream is
    # deterministic and regrouping never mutates the graph), so compute the
    # expensive full-DBG reorder + stack-distance simulations once per
    # (dataset, batch_size) and share them between the policy cells.
    cache_key = (key, batch_size)
    if shared_final is not None and cache_key in shared_final:
        final, levels, full_dbg, full_relabel_s, mpka_id, mpka_full = \
            shared_final[cache_key]
        if (final.num_vertices != svc.dg.num_vertices
                or final.num_edges != svc.dg.num_edges):
            raise RuntimeError(
                "update stream diverged across policies; the shared "
                "final-graph cache assumption no longer holds")
    else:
        final = svc.snapshot()
        levels = scaled_hierarchy(final.num_vertices)
        full_dbg = reorder.dbg(final.out_degrees())
        t0 = time.perf_counter()
        csr_mod.relabel(final, full_dbg.mapping)
        full_relabel_s = time.perf_counter() - t0
        mpka_id = layout_mpka(final, None, levels)
        mpka_full = layout_mpka(final, full_dbg.mapping, levels)
        if shared_final is not None:
            shared_final[cache_key] = (final, levels, full_dbg,
                                       full_relabel_s, mpka_id, mpka_full)

    cell = {
        "dataset": key,
        "policy": policy,
        "batch_size": batch_size,
        "num_batches": num_batches,
        "final_vertices": final.num_vertices,
        "final_edges": final.num_edges,
        "ingest_edges_per_second": edges_applied / max(1e-12, sum(ingest_s)),
        "ingest_seconds_per_batch": float(np.mean(ingest_s)),
        "query_latency_mean_s": float(np.mean(query_s)),
        "query_latency_median_s": float(np.median(query_s)),
        "pr_push_iters_mean": float(np.mean(pr_iters)),
        "compactions": svc.compactions,
        "regroup_seconds_per_batch": float(np.mean(regroup_s)),
        "moved_vertices_per_batch": float(np.mean(moved)),
        "full_dbg_mapping_seconds": full_dbg.seconds,
        "full_dbg_relabel_seconds": full_relabel_s,
        "mpka_identity": mpka_id,
        "mpka_full_dbg": mpka_full,
        # ingest-plane SLO burn rates at end of the timed pass
        # (machine-dependent — the regression gate skips it)
        "health": svc.health(),
    }
    if policy == "incremental_dbg":
        cell["mpka_incremental"] = layout_mpka(
            final, svc.current_mapping(), levels)
        cell["regroup_vs_full_dbg_cost_ratio"] = (
            cell["regroup_seconds_per_batch"]
            / max(1e-12, full_dbg.seconds + full_relabel_s))
    return cell


def sweep_hysteresis(key: str, scale: str, batch_size: int, num_batches: int,
                     h_values, seed: int = 3):
    """Moved-vertices/batch vs final MPKA as the hysteresis band varies."""
    cells = []
    for h in h_values:
        g = datasets.load(key, scale, seed=seed)
        svc = StreamService(g, StreamConfig(regroup_every=1, hysteresis=h))
        stream = ChurnStream(g, seed=seed)
        moved, regroup_s = [], []
        for _ in range(num_batches):
            a_s, a_d, d_s, d_d = stream.next_batch(svc.dg, batch_size)
            st = svc.ingest(add_src=a_s, add_dst=a_d,
                            del_src=d_s, del_dst=d_d)
            moved.append(st.moved_vertices)
            regroup_s.append(st.regroup_seconds)
        final = svc.snapshot()
        levels = scaled_hierarchy(final.num_vertices)
        m = layout_mpka(final, svc.current_mapping(), levels)
        cell = {
            "dataset": key,
            "batch_size": batch_size,
            "num_batches": num_batches,
            "hysteresis": h,
            "moved_vertices_per_batch": float(np.mean(moved)),
            "total_moved": int(np.sum(moved)),
            "regroup_seconds_per_batch": float(np.mean(regroup_s)),
            "mpka_final": m,
        }
        cells.append(cell)
        print(f"[stream_churn] sweep-h {key} h={h}: "
              f"{cell['moved_vertices_per_batch']:.1f} moved/batch, "
              f"final L3 mpka {m['l3_mpka']:.1f}", flush=True)
    return cells


def bench_dist_remap(key: str, scale: str, batch_size: int, num_batches: int,
                     seed: int = 3, n_shards: int = 4):
    """Shard-aware update routing vs full re-shard (the PR 5 acceptance row).

    A sharded deployment tracking a live stream used to re-shard from a full
    mapping whenever the grouping drifted; ``StreamService.apply_remaps_to``
    now patches only the group-crossers (``dist.graph.apply_remap``).  Per
    backend: mean per-batch patch cost vs one full ``shard_graph`` rebuild
    with the same final hot set — host-side work on both sides, no devices.
    """
    from repro.apps import engine as apps_engine
    from repro.dist import graph as dist_graph

    g = datasets.load(key, scale, seed=seed)
    ga = apps_engine.to_arrays(g, backend="arrays")
    cells = []
    for backend in ("flat", "ell"):
        # two identical passes over the same deterministic stream (the
        # bench_cell idiom): the first absorbs the one-time XLA compiles of
        # the slot/tile patch scatters, the second is timed
        for warmup in (True, False):
            svc = StreamService(g, StreamConfig(regroup_every=1))
            stream = ChurnStream(g, seed=seed)
            sg = dist_graph.shard_graph(ga, n_shards, backend=backend,
                                        remap_headroom=1.0)
            remap_s, overflows = [], 0
            for _ in range(num_batches):
                a_s, a_d, d_s, d_d = stream.next_batch(svc.dg, batch_size)
                svc.ingest(add_src=a_s, add_dst=a_d,
                           del_src=d_s, del_dst=d_d)
                t0 = time.perf_counter()
                try:
                    sg = svc.apply_remaps_to(sg)
                except dist_graph.RemapOverflow:
                    # rebuild around the regrouper's LIVE hot set (a default
                    # rebuild would revert to the stale static mask); the
                    # unconsumed deltas then replay as no-ops
                    overflows += 1
                    sg = dist_graph.shard_graph(
                        ga, n_shards, backend=backend, remap_headroom=1.0,
                        hot_override=svc.regrouper.hot_ids(
                            sg.hot_group_count))
                    sg = svc.apply_remaps_to(sg)
                remap_s.append(time.perf_counter() - t0)
        hot = np.flatnonzero(sg.host["hot_pos"] >= 0)
        t0 = time.perf_counter()
        dist_graph.shard_graph(ga, n_shards, backend=backend,
                               hot_override=hot, remap_headroom=1.0)
        full_s = time.perf_counter() - t0
        cell = {
            "dataset": key,
            "backend": backend,
            "n_shards": n_shards,
            "batch_size": batch_size,
            "num_batches": num_batches,
            "moved_total": int(sum(d.num_moved for d in svc.remap_deltas)),
            "apply_remap_seconds_per_batch": float(np.mean(remap_s)),
            "full_reshard_seconds": full_s,
            "remap_vs_reshard_ratio": float(np.mean(remap_s))
                                      / max(1e-12, full_s),
            "overflows": overflows,
        }
        cells.append(cell)
        print(f"[stream_churn] dist-remap {key}/{backend}: "
              f"{cell['apply_remap_seconds_per_batch']*1e3:.2f} ms/batch vs "
              f"full re-shard {full_s*1e3:.1f} ms "
              f"(ratio {cell['remap_vs_reshard_ratio']:.3f}, "
              f"{cell['moved_total']} moved)", flush=True)
    return cells


def bench_dist_ingest(key: str, scale: str, batch_size: int,
                      num_batches: int, seed: int = 3,
                      device_counts=(1, 2, 4, 8), backends=("flat",)):
    """Sustained sharded streaming ingest — the O(delta) batch path (PR 10).

    The same deterministic churn schedule drives a single-device
    ``StreamService`` and a ``ShardedStreamService`` side by side.  Timed:
    the per-batch shard routing (delta buffers + tombstone flips + per-shard
    compaction) vs ONE full ``shard_graph`` re-shard of the final graph —
    what a deployment without the delta path would pay per batch.  Parity is
    asserted, not just reported: SSSP answers must be bitwise equal and PR
    within the two-solver epsilon band, else the benchmark exits nonzero.
    """
    import jax

    from repro.apps import engine as apps_engine
    from repro.dist import graph as dist_graph
    from repro.dist import stream as dist_stream
    from repro.stream.sharded import ShardedStreamService

    g = datasets.load(key, scale, seed=seed)
    counts = [d for d in device_counts if d <= len(jax.devices())]
    cells = []
    for backend in backends:
        for d in counts:
            # two identical passes (the bench_cell idiom): the first absorbs
            # the jit compiles of the growing delta-buffer pads, the second
            # is timed
            for warmup in (True, False):
                ref = StreamService(g, StreamConfig(regroup_every=1))
                sh = ShardedStreamService(g, StreamConfig(regroup_every=1),
                                          n_shards=d, backend=backend)
                stream = ChurnStream(g, seed=seed)
                route_s, edges_applied, folds = [], 0, 0
                for _ in range(num_batches):
                    a_s, a_d, d_s, d_d = stream.next_batch(ref.dg, batch_size)
                    ref.ingest(add_src=a_s, add_dst=a_d,
                               del_src=d_s, del_dst=d_d)
                    st = sh.ingest(add_src=a_s, add_dst=a_d,
                                   del_src=d_s, del_dst=d_d)
                    info = sh.shard_history[-1]
                    route_s.append(info["seconds"])
                    folds += len(info.get("compacted", ())) \
                        + int(info["full_rebuild"])
                    edges_applied += st.inserted + st.deleted
            pr_dev = float(np.max(np.abs(ref.pagerank() - sh.pagerank())))
            sssp_ok = bool(np.array_equal(ref.sssp(0), sh.sssp(0)))
            # the O(E) alternative: one full re-shard of the final graph
            t0 = time.perf_counter()
            sg = dist_graph.shard_graph(
                apps_engine.to_arrays(ref.snapshot(), backend="arrays"),
                d, backend=backend, stream=True)
            dist_stream.sync_delta(sg)
            rebuild_s = time.perf_counter() - t0
            route_mean = float(np.mean(route_s))
            cell = {
                "dataset": key,
                "scale": scale,
                "backend": backend,
                "n_shards": d,
                "batch_size": batch_size,
                "num_batches": num_batches,
                "final_edges": ref.dg.num_edges,
                "ingest_edges_per_second":
                    edges_applied / max(1e-12, sum(route_s)),
                "route_seconds_per_batch": route_mean,
                "full_rebuild_seconds": rebuild_s,
                "incremental_vs_rebuild": rebuild_s / max(1e-12, route_mean),
                "full_rebuilds": sh.full_rebuilds,
                "shard_folds": folds,
                "pr_max_dev": pr_dev,
                "sssp_bitwise": sssp_ok,
            }
            cells.append(cell)
            print(f"[stream_churn] dist-ingest {key}/{backend} d={d} "
                  f"b={batch_size}: "
                  f"{cell['ingest_edges_per_second']/1e3:.1f} Ke/s routed, "
                  f"{route_mean*1e3:.2f} ms/batch vs rebuild "
                  f"{rebuild_s*1e3:.1f} ms "
                  f"({cell['incremental_vs_rebuild']:.1f}x), "
                  f"pr_dev {pr_dev:.2e} sssp_bitwise {sssp_ok}", flush=True)
            if not sssp_ok or pr_dev > 2e-7:
                print(f"[stream_churn] PARITY FAILURE in {key}/{backend} "
                      f"d={d}", file=sys.stderr, flush=True)
                sys.exit(1)
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="kr,uni")
    ap.add_argument("--scale", default="small")
    ap.add_argument("--batch-sizes", default="256,1024,4096")
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--sweep-h", default=None,
                    help="comma list of hysteresis values; adds the "
                         "hysteresis_sweep section (first batch size only)")
    ap.add_argument("--dist", action="store_true",
                    help="add the dist_ingest section: sharded streaming "
                         "ingest vs full re-shard, with parity asserts")
    ap.add_argument("--dist-devices", default="1,2,4,8",
                    help="device counts for --dist (clipped to available; "
                         "--smoke uses 1,2)")
    ap.add_argument("--dist-datasets", default=None,
                    help="datasets for --dist, each optionally 'key:scale' "
                         "(default: kr,lj:bench — the acceptance pair, lj "
                         "bumped to bench scale so its edge count matches "
                         "kr/small; --smoke follows --datasets)")
    ap.add_argument("--dist-backends", default=None,
                    help="backends for --dist (default: flat; --smoke uses "
                         "flat,ell for tile-path coverage)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: test scale, 2 batches, 1 size")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_stream.json"))
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.batches, args.batch_sizes = "test", 2, "64"

    batch_sizes = [int(x) for x in args.batch_sizes.split(",")]
    out = {"schema": 1, "scale": args.scale, "batches": args.batches,
           "cells": []}
    shared_final: dict = {}
    for key in args.datasets.split(","):
        for batch_size in batch_sizes:
            for policy in POLICIES:
                cell = bench_cell(key, args.scale, policy, batch_size,
                                  args.batches, shared_final=shared_final)
                out["cells"].append(cell)
                msg = (f"[stream_churn] {key} {policy} b={batch_size}: "
                       f"{cell['ingest_edges_per_second']/1e3:.1f} Ke/s "
                       f"query {cell['query_latency_median_s']*1e3:.1f} ms")
                if policy == "incremental_dbg":
                    msg += (f" regroup {cell['regroup_seconds_per_batch']*1e3:.2f}"
                            f" ms/batch (full dbg "
                            f"{(cell['full_dbg_mapping_seconds'] + cell['full_dbg_relabel_seconds'])*1e3:.1f} ms), "
                            f"L3 mpka inc {cell['mpka_incremental']['l3_mpka']:.1f}"
                            f" vs full {cell['mpka_full_dbg']['l3_mpka']:.1f}"
                            f" vs none {cell['mpka_identity']['l3_mpka']:.1f}")
                print(msg, flush=True)
    if args.sweep_h:
        h_values = [float(x) for x in args.sweep_h.split(",")]
        out["hysteresis_sweep"] = []
        for key in args.datasets.split(","):
            # largest batch size: enough degree churn per batch to exercise
            # the band (small batches rarely push a vertex past any margin)
            out["hysteresis_sweep"].extend(sweep_hysteresis(
                key, args.scale, max(batch_sizes), args.batches, h_values))
    out["dist_remap"] = []
    for key in args.datasets.split(","):
        out["dist_remap"].extend(bench_dist_remap(
            key, args.scale, max(batch_sizes), args.batches))
    if args.dist:
        devices = [int(x) for x in
                   ("1,2" if args.smoke else args.dist_devices).split(",")]
        dsets = args.dist_datasets or (args.datasets if args.smoke
                                       else "kr,lj:bench")
        backends = (args.dist_backends
                    or ("flat,ell" if args.smoke else "flat")).split(",")
        out["dist_ingest"] = []
        for spec in dsets.split(","):
            key, _, dscale = spec.partition(":")
            for batch_size in batch_sizes:
                out["dist_ingest"].extend(bench_dist_ingest(
                    key, dscale or args.scale, batch_size, args.batches,
                    device_counts=devices, backends=backends))
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[stream_churn] wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
