"""Serving throughput under churn: QPS vs batch width K → BENCH_serve.json.

The serving claim of ``repro.serve``: K concurrent queries share ONE fused
edge-map pass per iteration, so widening the batch raises QPS — while a
skew-preserving update stream (``stream_churn.ChurnStream``) keeps landing
delta batches between dispatches and snapshot isolation keeps every answer
pinned to one published graph version.

Per width K the harness replays the SAME deterministic workload twice
(stream_churn's warmup discipline — churn changes array shapes every publish,
so the first pass absorbs every jit compile and the second is timed):

  burst = ingest one churn batch (publishes a snapshot)
        → submit K queries (alternating sssp / personalized-pagerank bursts)
        → drain

and reports QPS (queries / wall-clock including the ingest share), latency
p50/p99, and batch occupancy from ``ServeMetrics``.  Every published version
is pinned during the timed pass, and a sampled SSSP answer is re-solved
from scratch on its pinned version graph and asserted BITWISE equal — the
snapshot-isolation check rides inside the benchmark.

Usage:
  PYTHONPATH=src python benchmarks/serve_qps.py [--dataset kr]
      [--scale small] [--widths 1,2,4,8] [--queries 24] [--churn 128]
      [--backend flat] [--out BENCH_serve.json] [--smoke]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax.numpy as jnp
import numpy as np

from repro.apps import to_arrays
from repro.graph import datasets
from repro.obs import counters as obs_counters
from repro.obs import flight as obs_flight
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.serve import (GraphServeService, Query, ServeConfig, batched_sssp)

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from stream_churn import ChurnStream  # noqa: E402


def bench_width(g, k: int, *, queries: int, churn: int, backend: str,
                seed: int = 0) -> dict:
    """(QPS, latency, occupancy) for batch width K over the churn stream."""
    v = g.num_vertices
    results, pins, elapsed = [], {}, 0.0
    counters = None
    for timed in (False, True):  # identical passes; first absorbs compiles
        if timed:
            # per-cell edge-map telemetry: fresh registry so the counter
            # columns cover exactly the timed pass
            counters = obs_counters.install(registry=MetricsRegistry())
        svc = GraphServeService(g, ServeConfig(
            max_width=k, max_depth=4 * k, backend=backend,
            pr_max_iters=15, publish_every=1))
        stream = ChurnStream(g, seed=seed)
        rng = np.random.default_rng(seed + 1)
        results, pins = [], {}
        t0 = time.perf_counter()
        burst = 0
        while len(results) < queries:
            a_s, a_d, d_s, d_d = stream.next_batch(svc.stream.dg, churn)
            svc.ingest(add_src=a_s, add_dst=a_d, del_src=d_s, del_dst=d_d)
            if timed:
                pins[svc.snapshot_version] = svc.store.acquire()
            kind = "sssp" if burst % 2 == 0 else "pagerank"
            for _ in range(min(k, queries - len(results))):
                svc.submit(Query(kind, root=int(rng.integers(0, v))))
            results.extend(svc.drain())
            burst += 1
        elapsed = time.perf_counter() - t0
        if not timed:
            continue
        # snapshot isolation, asserted inside the harness: a served SSSP
        # answer re-solved from scratch on its PINNED version graph is
        # bitwise identical, however much churn landed after its pin
        sample = next(r for r in reversed(results) if r.kind == "sssp")
        snap = pins[sample.snapshot_version]
        root = int(np.flatnonzero(sample.value == 0.0)[0])
        ref, _ = batched_sssp(to_arrays(snap.graph, backend=backend),
                              jnp.asarray([root], jnp.int32))
        np.testing.assert_array_equal(sample.value, np.asarray(ref[:, 0]))
        for snap in pins.values():
            svc.store.release(snap)
        summary = svc.metrics.summary()
        health = svc.health()
        obs_counters.uninstall()
    return {
        "width": k,
        "qps": round(len(results) / elapsed, 3),
        "latency_p50_ms": summary["latency_p50_ms"],
        "latency_p99_ms": summary["latency_p99_ms"],
        "occupancy": summary["occupancy"],
        "batches": summary["batches"],
        "ingest_batches": burst,
        "isolation_checked": True,
        # per-pass edge-map telemetry of the timed pass (repro.obs.counters)
        "counters": counters.summary(),
        # SLO burn rates + queue/snapshot state at end of the timed pass
        # (repro.obs.slo; machine-dependent — the regression gate skips it)
        "health": health,
    }


def serve_qps_pointer():
    """``benchmarks.run`` entry: the smoke cells (widths 1 and 4), returning
    the QPS-vs-width ratio as the derived value."""
    t0 = time.perf_counter()
    g = datasets.load("kr", "test", seed=0)
    cells = [bench_width(g, k, queries=8, churn=32, backend="flat")
             for k in (1, 4)]
    derived = {"qps_by_width": {str(c["width"]): c["qps"] for c in cells},
               "widest_over_serial_qps": round(
                   cells[-1]["qps"] / cells[0]["qps"], 2),
               "isolation_checked": all(c["isolation_checked"]
                                        for c in cells)}
    return (time.perf_counter() - t0) * 1e6, derived


BENCHES = [serve_qps_pointer]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kr")
    ap.add_argument("--scale", default="small")
    ap.add_argument("--widths", default="1,2,4,8")
    ap.add_argument("--queries", type=int, default=24,
                    help="queries served per width cell")
    ap.add_argument("--churn", type=int, default=128,
                    help="update-batch size ingested before every burst")
    ap.add_argument("--backend", default="flat")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config: test scale, widths 1,4, 8 queries")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome trace (serve/stream/engine spans) "
                         "and save it here — load in Perfetto")
    ap.add_argument("--flight", default=None, metavar="DIR",
                    help="arm the always-on flight recorder; anomaly dumps "
                         "(SLO breach, QueueFull, reclaim stall) land in DIR "
                         "plus a final flight_final.json ring snapshot")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_serve.json"))
    args = ap.parse_args()
    if args.smoke:
        args.scale, args.widths = "test", "1,4"
        args.queries, args.churn = 8, 32
    widths = [int(w) for w in args.widths.split(",")]
    if args.trace:
        obs_trace.enable()
    fr = obs_flight.install(dump_dir=args.flight) if args.flight else None

    g = datasets.load(args.dataset, args.scale, seed=0)
    out = {"schema": 1, "dataset": args.dataset, "scale": args.scale,
           "backend": args.backend, "queries_per_cell": args.queries,
           "churn_batch": args.churn, "cells": []}
    for k in widths:
        with obs_trace.span("bench.serve_width", cat="bench", width=k):
            cell = bench_width(g, k, queries=args.queries, churn=args.churn,
                               backend=args.backend)
        out["cells"].append(cell)
        print(f"[serve_qps] K={k}: {cell['qps']:.2f} qps, p50 "
              f"{cell['latency_p50_ms']:.1f} ms, p99 "
              f"{cell['latency_p99_ms']:.1f} ms, occupancy "
              f"{cell['occupancy']:.2f}", flush=True)

    qps = [c["qps"] for c in out["cells"]]
    out["summary"] = {
        "qps_by_width": {str(c["width"]): c["qps"] for c in out["cells"]},
        "qps_increases_with_width": qps[-1] > qps[0],
        "widest_over_serial_qps": round(qps[-1] / qps[0], 2),
        "isolation_checked": all(c["isolation_checked"]
                                 for c in out["cells"]),
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    if args.trace:
        print(f"[serve_qps] trace -> {obs_trace.save(args.trace)}",
              flush=True)
    if fr is not None:
        final = fr.dump(os.path.join(args.flight, "flight_final.json"))
        print(f"[serve_qps] flight ring ({len(fr)} events, "
              f"{len(fr.triggers)} anomalies) -> {final}", flush=True)
        obs_flight.uninstall()
    print(f"[serve_qps] wrote {args.out} (qps_increases_with_width="
          f"{out['summary']['qps_increases_with_width']}, widest/serial="
          f"{out['summary']['widest_over_serial_qps']}x)", flush=True)


if __name__ == "__main__":
    main()
