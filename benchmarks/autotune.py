"""Autotune driver: sweep the registry, write the plan → BENCH_tune.json.

For every registry graph this runs the full ``repro.tune`` loop:

  1. **price** the engine configuration space analytically (``tune.cost`` —
     the repo's own byte models through ``repro.roofline.HW``);
  2. **measure** the top-k shortlist plus deliberately-sampled non-shortlist
     probes under successive halving (``tune.search``), PageRank on the
     unweighted graph and SSSP on the weighted one;
  3. **select** the fastest byte-feasible candidate (never more modeled HBM
     bytes than the hand-tuned default — wall clock may win, the byte
     objective may not regress), refine SSSP's pull/push switch point, and
     choose the remaining apps' configs analytically (min modeled bytes,
     fully deterministic);
  4. **verify** the chosen backend against the flat oracle (min-reduction
     apps bitwise, sums to fp-association tolerance) — a plan that changes
     answers must never be written;
  5. **record** the honesty verdicts: ``honest_strict`` — the measured
     winner itself was shortlisted — and ``honest``, which also accepts a
     shortlisted candidate within 5% of the winner (tie-class noise).
     Logged per graph x app, summarized over the registry.

Pricing defaults to the ``cpu-interpret`` hardware profile (override via
``REPRO_HW_PROFILE``) because that is what the sweep measures on: under
the Pallas interpreter, per-grid-step dispatch dominates small-graph wall
clock, so the ranker must price it or its shortlist is uncorrelated with
the measurements it feeds.

Outputs: ``BENCH_tune.json`` (per-graph audit + plan-vs-default speedups)
and ``PLAN_tuned.json`` — the committed plan ``backend="auto"`` resolves.

``--select bytes`` makes selection purely analytic (modeled bytes, no
wall-clock in the decision) — the deterministic CI smoke mode gated by
``check_regression.py tune``.

Usage:
  PYTHONPATH=src python benchmarks/autotune.py [--scale small]
      [--datasets all|kr,lj,...] [--top-k 5] [--extras 4]
      [--select measured|bytes] [--smoke]
      [--out BENCH_tune.json] [--plan-out PLAN_tuned.json]
"""
import argparse
import datetime
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax.numpy as jnp
import numpy as np

from repro.apps import pagerank, sssp, to_arrays
from repro.graph import datasets
from repro.roofline import HW
from repro.tune import cost as tcost
from repro.tune import plan as tplan
from repro.tune import search as tsearch
from repro.tune import space as tspace

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: apps the sweep actually measures -> (graph flavor, runner app name)
MEASURED_APPS = ("pr", "sssp")
#: apps priced analytically only (min modeled bytes, deterministic)
ANALYTIC_APPS = ("prd", "bc", "radii")


def _max_dev(a, b) -> float:
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    mask = np.isfinite(a)
    if not np.array_equal(mask, np.isfinite(b)):
        return float("inf")
    if not mask.any():
        return 0.0
    scale = 1.0 + np.abs(a[mask]).max(initial=0.0)
    return float(np.abs(a[mask] - b[mask]).max(initial=0.0) / scale)


def _min_bytes_config(gc, grid, app: str) -> tcost.Scored:
    """Deterministic analytic choice: least modeled bytes, key tie-break."""
    ranked = tcost.rank(gc, grid, app=app)
    return min(ranked, key=lambda s: (s.model_bytes,
                                      tcost.config_key(s.config)))


def _verify(g, gw, engine_cfg: dict, oracle) -> dict:
    """Chosen backend vs the flat oracle: SSSP (min) bitwise, PR ~fp-assoc.
    Raises on disagreement — a wrong plan must never be written."""
    cfg = dict(engine_cfg)
    backend = cfg.pop("backend")
    ga, gaw = to_arrays(g, backend=backend, **cfg), \
        to_arrays(gw, backend=backend, **cfg)
    pr_flat, d_flat = oracle
    pr_dev = _max_dev(pr_flat, pagerank(ga)[0])
    d_cfg = np.asarray(sssp(gaw, jnp.int32(0))[0])
    sssp_bitwise = bool(np.array_equal(d_flat, d_cfg))
    if pr_dev > 1e-5 or not sssp_bitwise:
        raise SystemExit(
            f"tuned config {engine_cfg} disagrees with the flat oracle "
            f"(pr_dev={pr_dev}, sssp_bitwise={sssp_bitwise})")
    return {"pr_max_dev": pr_dev, "sssp_bitwise": sssp_bitwise}


def tune_graph(key: str, *, scale: str, top_k: int, extras: int,
               select: str, seed: int, audit: bool,
               refine_density: bool) -> dict:
    g = datasets.load(key, scale, seed=0)
    gw = datasets.load_weighted(key, scale, seed=0)
    space = tspace.engine_space()
    grid = space.grid()
    cell = {
        "dataset": key,
        "vertices": g.num_vertices,
        "edges": g.num_edges,
        "features": tplan.graph_features(g),
        "apps": {},
    }
    configs = {}
    default_engine = tspace.split_config(tspace.DEFAULT_CONFIG)[0]
    # full default incl. app-scope knobs — "knob absent" means "default value"
    default_full = tspace.canonical(dict(tspace.DEFAULT_CONFIG))

    # -- measured apps: analytic shortlist -> successive-halving sweep ------
    # rank under the profile we actually measure on: interpret-mode wall
    # clock is dominated by per-grid-step dispatch, not HBM traffic
    hw = HW.profile(os.environ.get("REPRO_HW_PROFILE", "cpu-interpret"))
    for app in MEASURED_APPS:
        graph = gw if app == "sssp" else g
        res = tsearch.sweep(graph, app=app, space=space, top_k=top_k,
                            extras=extras, seed=seed, select=select, hw=hw)
        gc = tcost.GraphCost.from_graph(graph)
        chosen = dict(res.chosen)
        density_timings = None
        if app == "sssp" and refine_density:
            chosen, density_timings = tsearch.refine_density_threshold(
                gw, chosen)
        chosen_bytes = tcost.app_bytes(
            gc, tspace.split_config(chosen)[0], app)
        default_bytes = tcost.default_budget(gc, app)
        configs[app] = chosen
        chosen_full = tspace.canonical({**tspace.DEFAULT_CONFIG, **chosen})
        engine_differs = (
            tcost.config_key(tspace.split_config(chosen)[0])
            != tcost.config_key(default_engine))
        tuned_wins = bool(engine_differs and res.speedup_vs_default > 1.0)
        row = {
            "measured": True,
            "chosen": chosen,
            "model_bytes": int(chosen_bytes),
            "default_bytes": int(default_bytes),
            "bytes_ratio": round(chosen_bytes / max(1, default_bytes), 6),
            "chosen_ms": round(res.chosen_s * 1e3, 3),
            "default_ms": round(res.default_s * 1e3, 3),
            "speedup_vs_default": round(res.speedup_vs_default, 4),
            "honest": res.honest,
            "honest_strict": res.honest_strict,
            "num_candidates": res.num_candidates,
            "num_measured": res.num_measured,
            "tuned_differs": tcost.config_key(chosen_full)
            != tcost.config_key(default_full),
        }
        if density_timings:
            # audit evidence for a density-threshold win: every switch point
            # was measured on the SAME engine config, same graph
            row["density_timings_ms"] = [
                [dt, round(s * 1e3, 3)]
                for dt, s in sorted(density_timings.items())]
            dt_c = chosen_full.get("density_threshold")
            dt_d = default_full.get("density_threshold")
            if dt_c != dt_d and dt_c in density_timings \
                    and dt_d in density_timings:
                tuned_wins = bool(
                    tuned_wins
                    or density_timings[dt_c] < density_timings[dt_d])
        row["tuned_wins"] = tuned_wins
        cell["apps"][app] = row
        if audit:
            cell["apps"][app]["trials"] = [t.to_json() for t in res.trials]

    # -- analytic-only apps: least modeled bytes, no measurement ------------
    for app in ANALYTIC_APPS:
        gc = tcost.GraphCost.from_graph(gw if app == "sssp" else g)
        best = _min_bytes_config(gc, grid, app)
        default_bytes = tcost.default_budget(gc, app)
        configs[app] = dict(best.config)
        cell["apps"][app] = {
            "measured": False,
            "chosen": dict(best.config),
            "model_bytes": int(best.model_bytes),
            "default_bytes": int(default_bytes),
            "bytes_ratio": round(best.model_bytes / max(1, default_bytes), 6),
        }

    # "default" plan entry: the PR choice (pull-dominated, the common shape)
    configs["default"] = dict(configs["pr"])
    cell["configs"] = configs
    cell["family"] = key

    # -- oracle verification of everything the plan will serve --------------
    verify_cfgs = {tcost.config_key(tspace.split_config(c)[0]):
                   tspace.split_config(c)[0] for c in configs.values()}
    oracle = (np.asarray(pagerank(to_arrays(g))[0]),
              np.asarray(sssp(to_arrays(gw), jnp.int32(0))[0]))
    devs = [_verify(g, gw, c, oracle) for c in verify_cfgs.values()]
    cell["correctness"] = {
        "configs_verified": len(devs),
        "pr_max_dev": max(d["pr_max_dev"] for d in devs),
        "sssp_bitwise": all(d["sssp_bitwise"] for d in devs),
    }

    pr_row = cell["apps"]["pr"]
    cell["tuned_differs"] = any(
        cell["apps"][a]["tuned_differs"] for a in MEASURED_APPS)
    cell["tuned_wins_wall_clock"] = any(
        cell["apps"][a]["tuned_wins"] for a in MEASURED_APPS)
    print(f"[autotune] {key}: pr {pr_row['chosen']} "
          f"{pr_row['speedup_vs_default']}x vs default "
          f"(bytes x{pr_row['bytes_ratio']}, honest={pr_row['honest']}) | "
          f"sssp {cell['apps']['sssp']['chosen']}", flush=True)
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="all",
                    help="comma list or 'all' (Table IX/X registry)")
    ap.add_argument("--scale", default="small")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--extras", type=int, default=4,
                    help="non-shortlist honesty probes measured per sweep")
    ap.add_argument("--select", choices=("measured", "bytes"),
                    default="measured")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny deterministic CI config: test scale, kr+road, "
                         "analytic (bytes) selection, no audit trail")
    ap.add_argument("--out", default=os.path.join(REPO_ROOT,
                                                  "BENCH_tune.json"))
    ap.add_argument("--plan-out", default=os.path.join(REPO_ROOT,
                                                       "PLAN_tuned.json"))
    args = ap.parse_args()
    audit, refine_density = True, args.select == "measured"
    if args.smoke:
        args.scale, args.datasets = "test", "kr,road"
        args.select, args.top_k, args.extras = "bytes", 3, 2
        audit, refine_density = False, False
    keys = (list(datasets.REGISTRY) if args.datasets == "all"
            else args.datasets.split(","))

    out = {"schema": 1, "scale": args.scale, "select": args.select,
           "top_k": args.top_k, "extras": args.extras, "cells": []}
    for key in keys:
        out["cells"].append(tune_graph(
            key, scale=args.scale, top_k=args.top_k, extras=args.extras,
            select=args.select, seed=args.seed, audit=audit,
            refine_density=refine_density))

    # -- summary: the acceptance criteria, computed where they are claimed --
    cells = out["cells"]
    honesty = {
        app: sum(1 for c in cells if c["apps"][app]["honest"])
        for app in MEASURED_APPS
    }
    honesty_strict = {
        app: sum(1 for c in cells if c["apps"][app]["honest_strict"])
        for app in MEASURED_APPS
    }
    bytes_never_worse = all(
        c["apps"][app]["bytes_ratio"] <= 1.0 + 1e-9
        for c in cells for app in c["apps"])
    out["summary"] = {
        "num_graphs": len(cells),
        "honesty": {app: f"{n}/{len(cells)}" for app, n in honesty.items()},
        "honesty_strict": {app: f"{n}/{len(cells)}"
                           for app, n in honesty_strict.items()},
        "honest_fraction": round(
            sum(honesty.values()) / max(1, len(cells) * len(MEASURED_APPS)),
            4),
        "bytes_never_worse_than_default": bytes_never_worse,
        "tuned_differs": [c["dataset"] for c in cells if c["tuned_differs"]],
        "tuned_differs_and_wins": [c["dataset"] for c in cells
                                   if c["tuned_wins_wall_clock"]],
    }

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    created = ("smoke" if args.smoke
               else datetime.datetime.now(datetime.timezone.utc)
               .strftime("%Y-%m-%dT%H:%M:%SZ"))
    plan = tplan.build_plan(
        cells, created=created,
        meta={"scale": args.scale, "select": args.select,
              "source": "benchmarks/autotune.py"})
    plan.save(args.plan_out)
    s = out["summary"]
    print(f"[autotune] wrote {args.out} and {args.plan_out} — "
          f"honesty {s['honesty']}, bytes_never_worse="
          f"{s['bytes_never_worse_than_default']}, tuned_differs_and_wins="
          f"{s['tuned_differs_and_wins']}", flush=True)


if __name__ == "__main__":
    main()
