"""Benchmarks reproducing the paper's static analysis tables (I-IV)."""
from __future__ import annotations

import time

from repro.core import stats

from . import common


def t1_skew():
    """Table I: hot-vertex % and edge coverage per dataset."""
    t0 = time.perf_counter()
    out = {}
    for key in common.SKEWED:
        out[key] = {k: round(v, 1) for k, v in
                    stats.hot_vertex_stats(common.graph(key)).items()}
    return (time.perf_counter() - t0) * 1e6, out


def t2_hot_per_block():
    """Table II: avg hot vertices per cache block (paper: 1.3-3.5)."""
    t0 = time.perf_counter()
    out = {k: round(stats.hot_per_cache_block(common.graph(k)), 2)
           for k in common.SKEWED}
    return (time.perf_counter() - t0) * 1e6, out


def t3_footprint():
    """Table III: MB needed for all hot vertices (8 and 16 B/vertex)."""
    t0 = time.perf_counter()
    out = {}
    for k in common.SKEWED:
        g = common.graph(k)
        out[k] = {
            "8B_mb": round(stats.hot_footprint_mb(g, bytes_per_vertex=8), 3),
            "16B_mb": round(stats.hot_footprint_mb(g, bytes_per_vertex=16), 3),
        }
    return (time.perf_counter() - t0) * 1e6, out


def t4_degree_dist():
    """Table IV: hot-vertex distribution across geometric ranges (sd)."""
    t0 = time.perf_counter()
    dist = stats.degree_range_distribution(common.graph("sd"))
    out = {k: {kk: round(vv, 2) for kk, vv in v.items()} for k, v in dist.items()}
    return (time.perf_counter() - t0) * 1e6, out


BENCHES = [t1_skew, t2_hot_per_block, t3_footprint, t4_degree_dist]
