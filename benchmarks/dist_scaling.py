"""Sharded-PageRank scaling benchmark → BENCH_dist.json.

Throughput of ``repro.dist.graph`` PageRank at 1/2/4/8 host devices, with and
without DBG hot-vertex replication, on the ``kr`` (unstructured RMAT) and
``lj`` (structured power-law) datasets — the device-level analogue of the
paper's cache experiments: replication shrinks the cold-halo all_to_all the
way DBG shrinks the hot working set.

Usage:
  PYTHONPATH=src python benchmarks/dist_scaling.py [--scale small]
      [--datasets kr,lj] [--iters 20] [--reps 3] [--out BENCH_dist.json]
"""
import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DIST_DEVICES", "8"),
)

import argparse
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import numpy as np

from repro.apps import engine
from repro.dist import graph as dist_graph
from repro.graph import datasets

POLICIES = ("replicate_hot", "partition")


def bench_cell(ga, n_dev: int, policy: str, iters: int, reps: int):
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n_dev]),
                             (dist_graph.AXIS,))
    sg = dist_graph.shard_graph(ga, n_dev, policy=policy)
    # tol=-1 forces exactly `iters` iterations — stable work per rep
    run = lambda: dist_graph.pagerank_sharded(sg, mesh, max_iters=iters,
                                              tol=-1.0)
    rank, _ = run()  # compile + warmup
    rank.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        rank, it = run()
    rank.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    edges = ga.num_edges * iters
    return {
        "n_devices": n_dev,
        "policy": policy,
        "seconds_per_run": dt,
        "edges_per_second": edges / dt,
        "iters": iters,
        **{k: sg.stats[k] for k in
           ("n_hot", "hot_frac", "halo_slots", "halo_bytes_padded",
            "edges_per_shard_max")},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="kr,lj")
    ap.add_argument("--scale", default="small")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_dist.json"))
    args = ap.parse_args()

    n_avail = len(jax.devices())
    requested = [int(x) for x in args.devices.split(",")]
    dev_counts = [x for x in requested if x <= n_avail]
    if len(dev_counts) < len(requested):
        print(f"[dist_scaling] only {n_avail} devices available; skipping "
              f"{sorted(set(requested) - set(dev_counts))}", flush=True)
    if not dev_counts:
        raise SystemExit(
            f"no runnable device counts in --devices {args.devices!r} "
            f"({n_avail} host devices; set REPRO_DIST_DEVICES to raise)")
    out = {"scale": args.scale, "iters": args.iters,
           "platform": jax.devices()[0].platform, "cells": []}
    for key in args.datasets.split(","):
        g = datasets.load(key, args.scale, seed=3)
        ga = engine.to_arrays(g)
        print(f"[dist_scaling] {key}: V={g.num_vertices} E={g.num_edges}",
              flush=True)
        base = {}
        for policy in POLICIES:
            for n in dev_counts:
                cell = bench_cell(ga, n, policy, args.iters, args.reps)
                cell["dataset"] = key
                if n == 1:
                    base[policy] = cell["seconds_per_run"]
                if policy in base:  # only meaningful vs a real 1-device run
                    cell["speedup_vs_1dev"] = (base[policy]
                                               / cell["seconds_per_run"])
                out["cells"].append(cell)
                print(f"[dist_scaling] {key} {policy} x{n}: "
                      f"{cell['edges_per_second']/1e6:.1f} Me/s "
                      f"(halo {cell['halo_slots']}, "
                      f"hot {cell['hot_frac']:.1%})", flush=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[dist_scaling] wrote {args.out}", flush=True)


if __name__ == "__main__":
    main()
