"""Sharded-PageRank scaling benchmark → BENCH_dist.json.

Throughput of ``repro.dist.graph`` PageRank at 1/2/4/8 host devices, with and
without DBG hot-vertex replication, on the ``kr`` (unstructured RMAT) and
``lj`` (structured power-law) datasets — the device-level analogue of the
paper's cache experiments: replication shrinks the cold-halo all_to_all the
way DBG shrinks the hot working set.

Since PR 5 the grid carries a ``--backends`` axis (names resolved through
``apps.engine.BACKENDS``): ``flat`` is the edge-parallel per-shard path,
``ell`` the fused per-shard DBG-ELL Pallas path.  Every cell reports the
analytic per-shard HBM bytes of one pull iteration for its backend
(``edge_map_bytes_sharded``), and a ``bytes_registry`` section prices
flat-vs-fused per-shard bytes on EVERY registry graph (host-side only — no
devices needed), which is the acceptance column: fused ≤ flat everywhere.

Usage:
  PYTHONPATH=src python benchmarks/dist_scaling.py [--scale small]
      [--datasets kr,lj] [--iters 20] [--reps 3] [--backends flat,ell]
      [--out BENCH_dist.json]
"""
import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DIST_DEVICES", "8"),
)

import argparse
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import numpy as np

from repro.apps import engine
from repro.dist import graph as dist_graph
from repro.graph import datasets

POLICIES = ("replicate_hot", "partition")


def bench_cell(ga, n_dev: int, policy: str, backend: str, iters: int,
               reps: int):
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n_dev]),
                             (dist_graph.AXIS,))
    sg = dist_graph.shard_graph(ga, n_dev, policy=policy, backend=backend,
                                track_remap=False)
    # tol=-1 forces exactly `iters` iterations — stable work per rep
    run = lambda: dist_graph.pagerank_sharded(sg, mesh, max_iters=iters,
                                              tol=-1.0)
    rank, _ = run()  # compile + warmup
    rank.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        rank, it = run()
    rank.block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    edges = ga.num_edges * iters
    return {
        "n_devices": n_dev,
        "policy": policy,
        "backend": backend,
        "seconds_per_run": dt,
        "edges_per_second": edges / dt,
        "iters": iters,
        "pull_bytes_per_shard": dist_graph.edge_map_bytes_sharded(
            sg, mode="pull", backend=backend),
        **{k: sg.stats[k] for k in
           ("n_hot", "hot_frac", "halo_slots", "halo_bytes_padded",
            "edges_per_shard_max")},
    }


def bytes_registry(scale: str, backends, n_shards: int = 4) -> dict:
    """Flat-vs-fused per-shard pull/push bytes on every registry graph.

    Pure host-side accounting (shard + tile-pack + the analytic byte model),
    so it covers the full Table IX/X registry regardless of device count.
    """
    out = {"n_shards": n_shards, "per_dataset": {}}
    worst = 0.0
    for key in datasets.REGISTRY:
        g = datasets.load(key, scale, seed=0)
        ga = engine.to_arrays(g, backend="arrays")
        sg = dist_graph.shard_graph(ga, n_shards, backend="ell",
                                    track_remap=False)
        cell = {}
        for b in backends:
            cell[b] = {
                "pull_bytes_per_shard": dist_graph.edge_map_bytes_sharded(
                    sg, mode="pull", backend=b),
                "push_bytes_per_shard": dist_graph.edge_map_bytes_sharded(
                    sg, mode="push", backend=b),
            }
        if "flat" in cell and "ell" in cell:
            r = max(cell["ell"]["pull_bytes_per_shard"]
                    / cell["flat"]["pull_bytes_per_shard"],
                    cell["ell"]["push_bytes_per_shard"]
                    / cell["flat"]["push_bytes_per_shard"])
            cell["fused_over_flat_worst"] = r
            worst = max(worst, r)
        out["per_dataset"][key] = cell
        print(f"[dist_scaling] bytes {key}: "
              + " ".join(f"{b} pull {cell[b]['pull_bytes_per_shard']/1e3:.0f}K"
                         for b in backends), flush=True)
    out["fused_bytes_le_flat_all"] = worst <= 1.0 if worst else None
    out["fused_over_flat_worst"] = worst
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default="kr,lj")
    ap.add_argument("--scale", default="small")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--backends", default="flat,ell",
                    help="comma list resolved through apps.engine.BACKENDS")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_dist.json"))
    args = ap.parse_args()
    backends = args.backends.split(",")
    for b in backends:  # fail fast on unknown names, via the one registry
        engine.resolve_backend(b)
        if b not in dist_graph.SHARDED_BACKENDS:
            raise SystemExit(f"backend {b!r} not supported by the sharded "
                             f"engine ({'|'.join(dist_graph.SHARDED_BACKENDS)})")

    n_avail = len(jax.devices())
    requested = [int(x) for x in args.devices.split(",")]
    dev_counts = [x for x in requested if x <= n_avail]
    if len(dev_counts) < len(requested):
        print(f"[dist_scaling] only {n_avail} devices available; skipping "
              f"{sorted(set(requested) - set(dev_counts))}", flush=True)
    if not dev_counts:
        raise SystemExit(
            f"no runnable device counts in --devices {args.devices!r} "
            f"({n_avail} host devices; set REPRO_DIST_DEVICES to raise)")
    out = {"scale": args.scale, "iters": args.iters,
           "platform": jax.devices()[0].platform, "cells": []}
    for key in args.datasets.split(","):
        g = datasets.load(key, args.scale, seed=3)
        ga = engine.to_arrays(g, backend="arrays")
        print(f"[dist_scaling] {key}: V={g.num_vertices} E={g.num_edges}",
              flush=True)
        base = {}
        for policy in POLICIES:
            for backend in backends:
                for n in dev_counts:
                    cell = bench_cell(ga, n, policy, backend, args.iters,
                                      args.reps)
                    cell["dataset"] = key
                    bkey = (policy, backend)
                    if n == 1:
                        base[bkey] = cell["seconds_per_run"]
                    if bkey in base:  # only meaningful vs a real 1-device run
                        cell["speedup_vs_1dev"] = (base[bkey]
                                                   / cell["seconds_per_run"])
                    out["cells"].append(cell)
                    print(f"[dist_scaling] {key} {policy}/{backend} x{n}: "
                          f"{cell['edges_per_second']/1e6:.1f} Me/s "
                          f"(halo {cell['halo_slots']}, "
                          f"hot {cell['hot_frac']:.1%}, pull "
                          f"{cell['pull_bytes_per_shard']/1e6:.2f} MB/shard)",
                          flush=True)
    if "ell" in backends:  # the flat-only grid doesn't need ELL tile packs
        out["bytes_registry"] = bytes_registry(args.scale, backends)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[dist_scaling] wrote {args.out} (fused_bytes_le_flat_all="
          f"{out.get('bytes_registry', {}).get('fused_bytes_le_flat_all')})",
          flush=True)


if __name__ == "__main__":
    main()
