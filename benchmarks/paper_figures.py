"""Benchmarks reproducing the paper's measured figures/tables (cache model +
measured reordering cost): Fig 3, Fig 6, Fig 7, Fig 8, Tables XI/XII,
Fig 10, Fig 11.
"""
from __future__ import annotations

import time

import numpy as np

from . import common


def f3_random_reorder():
    """Fig 3: slowdown of RV / RCB-1/2/4 (Radii-like pull traversal).
    Expected: structured datasets hurt badly by RV, less by coarser RCB;
    synthetic kr ~indifferent."""
    t0 = time.perf_counter()
    out = {}
    for key in common.SKEWED:
        row = {}
        for tech in ["random_vertex", "rcb1", "rcb2", "rcb4"]:
            s = common.app_speedup(key, tech, "pull", "out")
            row[tech] = round((1.0 / s - 1.0) * 100, 1)  # % slowdown
        out[key] = row
    common.save_json("f3_random_reorder.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f6_speedup():
    """Fig 6: per-app speedup (cache model, excluding reordering time) for all
    skew-aware techniques, all 8 datasets x 5 apps = 40 datapoints/technique."""
    t0 = time.perf_counter()
    table = {}
    for tech in common.TECHNIQUES[1:] + ["gorder_lite"]:
        per_app = {}
        all_pts = []
        for key in common.SKEWED:
            for app, mode, degsrc in common.APPS:
                s = common.app_speedup(key, tech, mode, degsrc)
                per_app[f"{key}.{app}"] = round((s - 1) * 100, 1)
                all_pts.append(s)
        table[tech] = {
            "mean_speedup_pct": round((common.geomean(all_pts) - 1) * 100, 1),
            "unstructured_pct": round((common.geomean(
                [v / 100 + 1 for k, v in per_app.items()
                 if k.split(".")[0] in common.UNSTRUCTURED]) - 1) * 100, 1),
            "structured_pct": round((common.geomean(
                [v / 100 + 1 for k, v in per_app.items()
                 if k.split(".")[0] in common.STRUCTURED]) - 1) * 100, 1),
            "per_datapoint": per_app,
        }
    common.save_json("f6_speedup.json", table)
    small = {t: {k: v for k, v in d.items() if k != "per_datapoint"}
             for t, d in table.items()}
    return (time.perf_counter() - t0) * 1e6, small


def f7_noskew():
    """Fig 7: skew-aware techniques must be ~neutral on no-skew datasets."""
    t0 = time.perf_counter()
    out = {}
    for key in common.NOSKEW:
        row = {}
        for tech in common.TECHNIQUES[1:]:
            pts = [common.app_speedup(key, tech, m, d)
                   for _, m, d in common.APPS]
            row[tech] = round((common.geomean(pts) - 1) * 100, 1)
        out[key] = row
    common.save_json("f7_noskew.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f8_mpki():
    """Fig 8: L1/L2/L3 MPKA for PR (pull) across datasets x techniques."""
    t0 = time.perf_counter()
    out = {}
    for key in common.SKEWED:
        row = {}
        for tech in common.TECHNIQUES:
            _, m, _, _ = common.sim(key, tech, "pull", "out")
            row[tech] = {k: round(v, 1) for k, v in m.items()}
        out[key] = row
    common.save_json("f8_mpki.json", out)
    sample = {k: out[k] for k in ["sd", "mp"]}
    return (time.perf_counter() - t0) * 1e6, sample


def t11_reorder_time():
    """Table XI: reordering time normalized to Sort (lower is better)."""
    t0 = time.perf_counter()
    out = {}
    for key in common.SKEWED:
        _, _, t_sort, _ = common.sim(key, "sort", "pull", "out")
        row = {}
        for tech in ["hubsort", "hubcluster", "dbg", "gorder_lite"]:
            _, _, secs, _ = common.sim(key, tech, "pull", "out")
            row[tech] = round(secs / max(t_sort, 1e-9), 2)
        out[key] = row
    common.save_json("t11_reorder_time.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def _iters_to_amortize(key, tech, iters_per_run=None):
    """Minimum PR iterations for the AMAT savings to cover the reorder cost."""
    a_base, _, _, n = common.sim(key, "original", "pull", "out")
    a_tech, _, secs, _ = common.sim(key, tech, "pull", "out")
    cyc_saved = (a_base - a_tech) * n
    if cyc_saved <= 0:
        return float("inf")
    sec_saved_per_iter = cyc_saved / (common.CPU_GHZ * 1e9)
    return secs / sec_saved_per_iter


def t12_amortization():
    """Table XII: min PR iterations to amortize reordering cost."""
    t0 = time.perf_counter()
    out = {}
    for key in ["tw", "sd", "fr", "mp"]:
        row = {}
        for tech in ["sort", "hubsort", "hubcluster", "dbg", "gorder_lite"]:
            it = _iters_to_amortize(key, tech)
            row[tech] = round(it, 1) if np.isfinite(it) else "never"
        out[key] = row
    common.save_json("t12_amortization.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f10_net_speedup():
    """Fig 10: end-to-end speedup INCLUDING reorder cost, one PR-to-
    convergence run (64 iterations)."""
    t0 = time.perf_counter()
    iters = 64
    out = {}
    for key in ["tw", "sd", "fr", "mp"]:
        a_base, _, _, n = common.sim(key, "original", "pull", "out")
        t_base = iters * n * (common.C_COMPUTE + a_base) / (common.CPU_GHZ * 1e9)
        row = {}
        for tech in common.TECHNIQUES[1:] + ["gorder_lite"]:
            a, _, secs, _ = common.sim(key, tech, "pull", "out")
            t_tech = secs + iters * n * (common.C_COMPUTE + a) / (common.CPU_GHZ * 1e9)
            row[tech] = round((t_base / t_tech - 1) * 100, 1)
        out[key] = row
    common.save_json("f10_net_speedup.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f11_sssp_traversals():
    """Fig 11: SSSP net speedup vs number of traversals (1..32)."""
    t0 = time.perf_counter()
    out = {}
    for n_trav in [1, 8, 16, 32]:
        row = {}
        for tech in common.TECHNIQUES[1:]:
            pts = []
            for key in ["tw", "sd", "fr", "mp"]:
                a_base, _, _, n = common.sim(key, "original", "push", "in")
                a, _, secs, _ = common.sim(key, tech, "push", "in")
                t_base = n_trav * n * (common.C_COMPUTE + a_base) / (common.CPU_GHZ * 1e9)
                t_tech = secs + n_trav * n * (common.C_COMPUTE + a) / (common.CPU_GHZ * 1e9)
                pts.append(t_base / t_tech)
            row[tech] = round((common.geomean(pts) - 1) * 100, 1)
        out[f"traversals_{n_trav}"] = row
    common.save_json("f11_sssp_traversals.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f5_impl_comparison():
    """Fig 5-style: HubSort/HubCluster via the DBG framework vs 'original'
    single-shot implementations — here we verify framework-derived mappings
    equal the direct implementations (Table V equivalence), and compare time."""
    import numpy as np

    from repro.core import reorder

    t0 = time.perf_counter()
    out = {}
    for key in ["tw", "mp"]:
        g = common.graph(key)
        degs = g.out_degrees()
        a = max(1.0, degs.mean())
        t1 = time.perf_counter()
        hc_direct = reorder.hubcluster(degs)
        t_direct = time.perf_counter() - t1
        t1 = time.perf_counter()
        hc_fw = reorder.group_reorder(degs, reorder.hubcluster_spec(a))
        t_fw = time.perf_counter() - t1
        assert np.array_equal(hc_direct.mapping, hc_fw.mapping)
        out[key] = {"direct_s": round(t_direct, 4), "framework_s": round(t_fw, 4)}
    common.save_json("f5_impl_comparison.json", out)
    return (time.perf_counter() - t0) * 1e6, out


def f9_push_coherence():
    """Fig 9 analogue (DESIGN.md §2): the paper's multi-socket coherence
    traffic maps to cross-device scatter traffic at cluster scale.  Partition
    vertices into 16 contiguous shards (the distributed layout implied by the
    ordering); a push crosses the 'socket'/device boundary iff src and dst
    live on different shards.  DBG should REDUCE the remote fraction on
    structured datasets (community members stay co-located) while random
    reordering maximizes it."""
    import numpy as np

    from repro.graph import csr as csr_mod

    t0 = time.perf_counter()
    n_shards = 16
    out = {}
    for key in ["sd", "mp", "fr"]:
        g = common.graph(key)

        def remote_frac(graph):
            src, dst, _ = csr_mod.to_edges(graph)
            shard = lambda v: v * n_shards // graph.num_vertices
            return float(np.mean(shard(src) != shard(dst)))

        row = {"original": round(100 * remote_frac(g), 1)}
        for tech in ["dbg", "hubcluster", "sort", "random_vertex"]:
            g2, _ = common.reorder.reorder_graph(g, tech, degree_source="in")
            row[tech] = round(100 * remote_frac(g2), 1)
        out[key] = row
    common.save_json("f9_push_coherence.json", out)
    return (time.perf_counter() - t0) * 1e6, out


# re-bind with f9 now defined (appended after the original list)
BENCHES = [f3_random_reorder, f5_impl_comparison, f6_speedup, f7_noskew,
           f8_mpki, f9_push_coherence, t11_reorder_time, t12_amortization,
           f10_net_speedup, f11_sssp_traversals]
